"""Observability pipeline (DESIGN.md §11): telemetry delta streaming,
the dashboard API, replay-testable anomaly detection over the full
regime corpus, and the streaming trace codec."""

import json
import urllib.error
import urllib.request

import jax
import numpy as np
import pytest

from _hyp import given, settings, st
from repro.control import FailQueues, ProgramReta, SwapSlot
from repro.core import executor
from repro.core import packet as pkt
from repro.dataplane import (DataplaneRuntime, MeshDataplane, faults,
                             telemetry as telemetry_mod, workloads)
from repro.dataplane.workloads import generators
from repro.dataplane.workloads import trace as trace_mod
from repro.obs import AnomalyDetector, TelemetryStream, attach, detach
from repro.obs import spans
from repro.obs.anomaly import RetrainRequest
from repro.obs.server import ObsServer


@pytest.fixture(scope="module")
def bank2():
    return executor.init_bank(jax.random.PRNGKey(0), 2)


#: regimes whose detection evidence needs the mesh + armed fault plan
MESH_REGIMES = ("cascading-failover", "chaos-host-failover",
                "barrier-straggler", "crash-mid-commit")


def _state_fingerprint(state: dict):
    """The routing-state keys shared by runtime and mesh snapshots."""
    return (np.asarray(state["reta"]).tolist(), sorted(state["failed"]),
            np.asarray(state["bucket_load"]).tolist(),
            state["slot_swaps"], state["reta_updates"])


def _regime_setup(bank, regime):
    hosts = 2 if regime in MESH_REGIMES else 1
    queues = 2 if regime in MESH_REGIMES else 4
    w = workloads.make_workload(
        regime, num_slots=2, num_queues=queues, hosts=hosts,
        corpus_root=generators.SYNTHETIC_CORPUS)
    trace = workloads.synthesize(
        w.phases, num_slots=2, num_queues=hosts * queues, seed=0,
        name=regime, payload_pool=w.payload_pool)
    kw = dict(batch=128, ring_capacity=4096, record=True)
    if hosts > 1:
        injector = (faults.FaultInjector(w.fault_plan)
                    if w.fault_plan is not None else None)
        rt = MeshDataplane(bank, hosts=hosts, num_queues=queues,
                           fault_injector=injector, **kw)
    else:
        rt = DataplaneRuntime(bank, num_queues=queues, **kw)
    return rt, trace, hosts, hosts * queues


def _packets(rng, n, num_slots=2):
    slots = rng.integers(0, num_slots, n)
    payload = rng.integers(0, 2**32, (n, pkt.PAYLOAD_WORDS), dtype=np.uint32)
    return pkt.make_packets(slots, payload)


# ---------------------------------------------------------------------------
# delta stream
# ---------------------------------------------------------------------------

def _fold(events):
    """Sum a delta-event list back into cumulative totals."""
    tot = {"completed": {}, "dropped": {}, "per_slot": {}, "actions": {},
           "events": {}}
    for ev in events:
        if ev.get("kind") != "delta":
            continue
        for q in ev["queues"]:
            qid = q["queue"]
            tot["completed"][qid] = tot["completed"].get(qid, 0) + q["completed"]
            tot["dropped"][qid] = tot["dropped"].get(qid, 0) + q["dropped"]
            tot["per_slot"][qid] = (np.asarray(q["per_slot"])
                                    + tot["per_slot"].get(qid, 0))
            tot["actions"][qid] = (np.asarray(q["actions"])
                                   + tot["actions"].get(qid, 0))
        for name, d in ev["events"].items():
            tot["events"][name] = tot["events"].get(name, 0) + d
    return tot


def _assert_stream_matches_snapshot(rt, events):
    snap = rt.telemetry.snapshot()
    tot = _fold(events)
    for q in snap["queues"]:
        qid = q["queue"]
        assert tot["completed"].get(qid, 0) == q["completed"]
        assert tot["dropped"].get(qid, 0) == q["dropped"]
        if q["completed"]:
            assert np.array_equal(tot["per_slot"][qid], q["per_slot_total"])
    for name in telemetry_mod.EVENT_COUNTERS:
        assert tot["events"].get(name, 0) == snap[name], name


def test_delta_stream_sums_to_snapshot_on_replay(bank2):
    rt, trace, _, _ = _regime_setup(bank2, "emergency")
    events = []
    rt_tele = rt.telemetry
    rt_tele.attach_sink(events.append)
    workloads.replay(trace, rt)
    assert events, "no deltas emitted"
    assert [e["seq"] for e in events] == list(range(len(events)))
    _assert_stream_matches_snapshot(rt, events)
    # rollback epochs may legitimately emit negative event deltas;
    # the stream must still SUM to the live counters (checked above)
    assert all(q["completed"] >= 0 for e in events for q in e["queues"])


@settings(max_examples=15, deadline=None)
@given(st.lists(st.tuples(st.integers(1, 80), st.booleans()),
                min_size=1, max_size=10),
       st.integers(0, 2**31 - 1))
def test_delta_stream_sum_property(bank2, plan, seed):
    """Any dispatch/tick interleaving: delta stream sums to snapshot()."""
    rng = np.random.default_rng(seed)
    rt = DataplaneRuntime(bank2, num_queues=3, batch=32, ring_capacity=64)
    events = []
    rt.telemetry.attach_sink(events.append)
    for n, do_tick in plan:
        rt.dispatch(_packets(rng, n))  # tiny ring: drops exercised too
        if do_tick:
            rt.tick()
    rt.drain()
    rt.retire_all()
    _assert_stream_matches_snapshot(rt, events)


def test_first_delta_carries_preattach_counters(bank2):
    rng = np.random.default_rng(1)
    rt = DataplaneRuntime(bank2, num_queues=2, batch=64, ring_capacity=256)
    rt.dispatch(_packets(rng, 32))
    rt.drain()
    events = []
    rt.telemetry.attach_sink(events.append)  # cursor resets on attach
    rt.dispatch(_packets(rng, 16))
    rt.drain()
    _assert_stream_matches_snapshot(rt, events)
    first_total = sum(q["completed"] for q in events[0]["queues"])
    assert first_total >= 32  # pre-attach work is in the first delta


def test_stream_ring_cursor_and_overflow():
    stream = TelemetryStream(capacity=8)
    for i in range(20):
        stream.push({"kind": "delta", "i": i})
    assert len(stream) == 8
    assert stream.dropped_events == 12
    events, cur = stream.tail(0)  # stale cursor resumes at oldest
    assert [e["i"] for e in events] == list(range(12, 20))
    assert cur == 20
    events, cur = stream.tail(cur)
    assert events == [] and cur == 20
    stream.push({"kind": "delta", "i": 20})
    events, cur = stream.tail(cur, limit=1)
    assert [e["i"] for e in events] == [20]


def test_epoch_and_health_spans_on_stream(bank2):
    rt, trace, _, _ = _regime_setup(bank2, "crash-mid-commit")
    stream = TelemetryStream()
    attach(rt, stream)
    workloads.replay(trace, rt)
    kinds = {e["kind"] for e in stream.latest(10_000)}
    assert {"delta", "epoch", "health"} <= kinds
    epochs = [e for e in stream.latest(10_000) if e["kind"] == "epoch"]
    for e in epochs:
        span = e["span"]
        assert span["outcome"] in ("atomic", "degraded", "rollback")
        if span["apply_us"] is not None:
            assert span["total_us"] >= span["apply_us"] >= 0
            assert span["queued_us"] >= 0
    # the mesh epoch log and the stream saw the same epochs
    assert len(epochs) == len(rt.control.log)
    detach(rt)
    assert not rt.shards[0].telemetry.has_sink


# ---------------------------------------------------------------------------
# telemetry merge under uneven host ticking
# ---------------------------------------------------------------------------

def test_merge_carries_event_counters_and_aligns_windows():
    a = telemetry_mod.Telemetry(2, 2)
    b = telemetry_mod.Telemetry(2, 2)
    a.runtime_ticks, b.runtime_ticks = 40, 3  # b stalled most of the run
    a.slot_swaps, b.slot_swaps = 2, 1
    a.reta_updates, b.reta_updates = 1, 0
    a.record_drops(0, 5, now=10.0)
    b.record_drops(1, 7, now=10.5)
    a.queues[0].record(np.array([0, 1]), np.array([False, False]),
                       np.array([0, 0]), np.array([1.0, 1.0]), 0.01)
    a.touch(18.0)   # a covered 10.0 .. 18.0
    b.touch(11.0)   # b covered 10.5 .. 11.0 (crashed early)
    m = telemetry_mod.merge([a, b])
    assert m.runtime_ticks == 43
    assert m.slot_swaps == 3 and m.reta_updates == 1
    assert m.dropped_total == 12
    # union window, not either host's own: 10.0 .. 18.0
    assert m.window_start_s == 10.0 and m.window_last_s == 18.0
    snap = m.snapshot()
    assert snap["runtime_ticks"] == 43 and snap["dropped_total"] == 12
    assert snap["aggregate_pps"] == pytest.approx(2 / 8.0)


def test_mesh_snapshot_merge_matches_shard_sums(bank2):
    rt, trace, _, _ = _regime_setup(bank2, "chaos-host-failover")
    workloads.replay(trace, rt)
    snap = rt.snapshot()
    assert snap["runtime_ticks"] == sum(
        s.telemetry.runtime_ticks for s in rt.shards)
    assert snap["dropped_total"] == sum(
        s.telemetry.dropped_total for s in rt.shards)


# ---------------------------------------------------------------------------
# anomaly detection over the full corpus
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("regime", workloads.REGIME_NAMES)
def test_detector_classifies_regime(bank2, regime):
    rt, trace, hosts, num_queues = _regime_setup(bank2, regime)
    stream = TelemetryStream(capacity=1 << 16)
    attach(rt, stream)
    det = AnomalyDetector(stream, num_queues=num_queues, num_slots=2,
                          hosts=hosts)
    workloads.replay(trace, rt)
    det.poll()
    got = det.classify()
    assert got["regime"] == regime, (got["regime"], got["evidence"])
    assert det.detect_tick() is not None

    # proposals must stage-accept without mutating the control plane
    # (RetrainRequest is a deploy-plane proposal, not a control command;
    # SwapSlot proposals are specs — materialized before staging, the
    # trace-format convention)
    before = rt.control.stats()["epochs_applied"]
    state_before = _state_fingerprint(rt._control_state())
    for cmd in det.proposals():
        if isinstance(cmd, RetrainRequest):
            assert cmd.describe()["cmd"] == "retrain"
            continue
        assert isinstance(cmd, (ProgramReta, FailQueues, SwapSlot))
        rt._validate_command(workloads.materialize_command(cmd))
    assert rt.control.stats()["epochs_applied"] == before
    assert _state_fingerprint(rt._control_state()) == state_before


def test_detector_proposes_failover_for_silent_queue():
    """A backlogged queue that stops completing draws a FailQueues
    proposal (unit-level: crafted deltas, no runtime)."""
    stream = TelemetryStream()
    det = AnomalyDetector(stream, num_queues=2, num_slots=2,
                          silence_ticks=3)
    for tick in range(10):
        q1_done = 32 if tick < 3 else 0  # completes early, then stalls
        stream.push({"kind": "delta", "seq": tick, "tick": tick,
                     "t_s": None, "host": 0,
                     "queues": [{"queue": 0, "completed": 64, "dropped": 0,
                                 "per_slot": [32, 32], "actions": [64, 0, 0],
                                 "depth": 0},
                                {"queue": 1, "completed": q1_done,
                                 "dropped": 0, "per_slot": [q1_done, 0],
                                 "actions": [q1_done, 0, 0],
                                 "depth": 40}],
                     "events": {}})
    det.poll()
    assert any(f.detector == "queue_silence" for f in det.findings)
    props = det.proposals()
    fails = [c for c in props if isinstance(c, FailQueues)]
    assert fails and 1 in fails[0].queues


def _delta(tick, queues):
    return {"kind": "delta", "seq": tick, "tick": tick, "t_s": None,
            "host": 0, "queues": queues, "events": {}}


def test_detector_proposes_retrain_on_slot_mix_shift():
    """A flipped slot mix draws a SwapSlot *spec* (params=None) plus a
    RetrainRequest for the now-dominant slot (unit-level: crafted
    deltas, no runtime)."""
    stream = TelemetryStream()
    det = AnomalyDetector(stream, num_queues=2, num_slots=2, window=4)
    for tick in range(16):
        per_slot = [64, 0] if tick < 8 else [0, 64]  # mix flips at t=8
        stream.push(_delta(tick, [
            {"queue": 0, "completed": 64, "dropped": 0,
             "per_slot": per_slot, "actions": [64, 0, 0], "depth": 0},
            {"queue": 1, "completed": 60, "dropped": 0,
             "per_slot": per_slot, "actions": [60, 0, 0], "depth": 0}]))
    det.poll()
    assert any(f.detector == "slot_mix_shift" for f in det.findings)
    props = det.proposals()
    swaps = [c for c in props if isinstance(c, SwapSlot)]
    retrains = [c for c in props if isinstance(c, RetrainRequest)]
    assert swaps and swaps[0].slot == 1 and swaps[0].params is None
    assert retrains and retrains[0].slot == 1
    assert retrains[0].reason == "slot_mix_shift"
    assert retrains[0].describe()["cmd"] == "retrain"


def test_detector_proposes_retrain_on_drop_surge():
    """A sustained drop surge without routing skew (balanced queues)
    means the model, not the RETA, mismatches the traffic -> retrain."""
    stream = TelemetryStream()
    det = AnomalyDetector(stream, num_queues=2, num_slots=2, window=4)
    for tick in range(12):
        drops = 0 if tick < 6 else 24  # ring-edge drops start at t=6
        stream.push(_delta(tick, [
            {"queue": 0, "completed": 64, "dropped": drops,
             "per_slot": [64, 0], "actions": [64, 0, 0], "depth": 0},
            {"queue": 1, "completed": 60, "dropped": drops,
             "per_slot": [60, 0], "actions": [60, 0, 0], "depth": 0}]))
    det.poll()
    assert any(f.detector == "drop_surge" for f in det.findings)
    assert det.classify()["regime"] != "elephant-skew"
    retrains = [c for c in det.proposals()
                if isinstance(c, RetrainRequest)]
    assert retrains and retrains[0].slot == 0
    assert retrains[0].reason == "drop_surge"


# ---------------------------------------------------------------------------
# dashboard API
# ---------------------------------------------------------------------------

def test_server_endpoints(bank2):
    rt, trace, _, _ = _regime_setup(bank2, "emergency")
    stream = TelemetryStream()
    attach(rt, stream)
    det = AnomalyDetector(stream, num_queues=4, num_slots=2)
    with ObsServer(rt, stream, detector=det) as srv:
        workloads.replay(trace, rt)
        base = f"http://127.0.0.1:{srv.port}"

        def get(ep):
            return json.load(urllib.request.urlopen(base + ep, timeout=10))

        assert get("/healthz")["ok"]
        m = get("/metrics")
        snap = rt.telemetry.snapshot()
        assert m["totals"]["completed"] == snap["completed_total"]
        assert m["totals"]["dropped"] == snap["dropped_total"]
        assert len(m["queues"]) == 4
        e = get("/epochs")
        assert e["api_version"] == rt.control.API_VERSION
        assert len(e["epochs"]) == len(rt.control.log)
        assert all("span" in rec for rec in e["epochs"])
        # /epochs serves the SAME document --epoch-log-json writes
        from repro.obs.server import _json_default
        assert e == json.loads(json.dumps(
            spans.epoch_log_doc(rt), default=_json_default))
        a = get("/anomaly")
        assert a["enabled"] and a["regime"] == "emergency"
        assert all(isinstance(p, dict) and "cmd" in p
                   for p in a["proposals"])
        html = urllib.request.urlopen(base + "/", timeout=10).read()
        assert b"dataplane observer" in html
        with pytest.raises(urllib.error.HTTPError):
            urllib.request.urlopen(base + "/nope", timeout=10)


# ---------------------------------------------------------------------------
# streaming trace codec
# ---------------------------------------------------------------------------

def _record_run(bank, path=None):
    w = workloads.make_workload("emergency", num_slots=2, num_queues=4)
    rendered = workloads.render(list(w.phases), num_slots=2, seed=3,
                                num_queues=4, payload_pool=w.payload_pool)
    rt = DataplaneRuntime(bank, num_queues=4, batch=128,
                          ring_capacity=4096, record=True)
    rec = workloads.record(rt, path=path)
    workloads.play(rec, rendered)
    return rec.finish(name="emergency", seed=3)


def test_streamed_recording_matches_buffered_save(bank2, tmp_path):
    buffered = _record_run(bank2)
    buf_path = str(tmp_path / "buffered.bswt")
    workloads.save(buffered, buf_path)
    stream_path = str(tmp_path / "streamed.bswt")
    streamed = _record_run(bank2, path=stream_path)
    assert isinstance(streamed, workloads.StreamedTrace)
    assert streamed.steps == len(buffered.steps)
    assert streamed.total_packets == buffered.total_packets
    assert (open(buf_path, "rb").read()
            == open(stream_path, "rb").read())
    loaded = workloads.load(stream_path)
    assert all(
        np.array_equal(s1["rows"], s2["rows"])
        for s1, s2 in zip(buffered.steps, loaded.steps)
        if s1["kind"] == "burst")
    rep = workloads.replay(loaded, workloads.make_runtime(loaded))
    assert rep["ok"] and rep["digest_ok"]


def test_v1_monolithic_traces_still_load(bank2, tmp_path):
    trace = _record_run(bank2)
    path = str(tmp_path / "old.bswt")
    trace_mod._save_v1(trace, path)
    with open(path, "rb") as f:
        assert f.read(9)[-1] == 1  # genuinely on-disk v1
    loaded = workloads.load(path)
    rep = workloads.replay(loaded, workloads.make_runtime(loaded))
    assert rep["ok"] and rep["digest_ok"]


def test_unfinished_streaming_recording_rejected(bank2, tmp_path):
    path = str(tmp_path / "partial.bswt")
    rt = DataplaneRuntime(bank2, num_queues=2, batch=64, ring_capacity=256)
    rec = workloads.record(rt, path=path)
    rng = np.random.default_rng(0)
    for _ in range(40):  # enough bytes to flush at least one chunk
        rec.dispatch(_packets(rng, 64))
        rec.tick()
    rec.abort()
    with pytest.raises(ValueError, match="tail chunk"):
        workloads.load(path)


def test_streaming_recorder_bounds_buffering(bank2, tmp_path):
    """Chunks hit the disk DURING the run, not at finish()."""
    import os
    path = str(tmp_path / "grow.bswt")
    rt = DataplaneRuntime(bank2, num_queues=2, batch=64, ring_capacity=1024)
    rec = workloads.record(rt, path=path, chunk_bytes=1 << 14)
    rng = np.random.default_rng(0)
    sizes = []
    for _ in range(12):
        rec.dispatch(_packets(rng, 64))
        rec.tick()
        sizes.append(os.path.getsize(path))
    assert sizes[-1] > sizes[0] > 0
    rec.finish(name="grow", seed=0)
    loaded = workloads.load(path)
    assert loaded.meta["name"] == "grow"


# ---------------------------------------------------------------------------
# launch CLI: --epoch-log-json
# ---------------------------------------------------------------------------

def test_cli_epoch_log_json(tmp_path, capsys):
    from repro.launch import dataplane as launch
    out = tmp_path / "epochs.json"
    launch.main(["--scenario", "emergency", "--queues", "2", "--slots", "2",
                 "--ring-capacity", "2048", "--epoch-log-json", str(out)])
    doc = json.loads(out.read_text())
    assert doc["epochs"], "no epochs in log"
    assert doc["continuity"]["ok"]
    for rec in doc["epochs"]:
        assert "span" in rec and "commands" in rec
    assert doc["stats"]["epochs_applied"] >= len(
        [r for r in doc["epochs"] if r["commit_mode"] == "atomic"])
