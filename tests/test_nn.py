"""NN substrate: flash attention vs quadratic oracle, SSD vs sequential,
MoE dispatch invariants."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st

from repro.configs.base import ModelConfig
from repro.nn import modules, moe as moe_lib, ssd


def _cfg(**kw):
    base = dict(name="t", family="dense", n_layers=1, d_model=64, n_heads=4,
                n_kv_heads=2, d_ff=128, vocab_size=256, dtype="float32")
    base.update(kw)
    return ModelConfig(**base)


def _ref_attn(p, x, cfg, pos, causal=True):
    b, s, _ = x.shape
    hq, g, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = (x @ p["wq"]).reshape(b, s, hq, hd)
    k = (x @ p["wk"]).reshape(b, s, g, hd)
    v = (x @ p["wv"]).reshape(b, s, g, hd)
    q = modules.rope(q, pos, cfg.rope_theta)
    k = modules.rope(k, pos, cfg.rope_theta)
    q = q.reshape(b, s, g, hq // g, hd)
    sc = jnp.einsum("bqghd,bkgd->bghqk", q, k,
                    preferred_element_type=jnp.float32) * hd ** -0.5
    i = jnp.arange(s)
    mask = jnp.ones((s, s), bool)
    if causal:
        mask &= i[None, :] <= i[:, None]
    if cfg.sliding_window:
        mask &= (i[:, None] - i[None, :]) < cfg.sliding_window
    sc = jnp.where(mask[None, None, None], sc, jnp.finfo(jnp.float32).min)
    pr = jax.nn.softmax(sc, -1)
    o = jnp.einsum("bghqk,bkgd->bghqd", pr.astype(v.dtype), v,
                   preferred_element_type=jnp.float32).astype(x.dtype)
    return o.transpose(0, 3, 1, 2, 4).reshape(b, s, hq * hd) @ p["wo"]


@pytest.mark.parametrize("window,qb,kb,heads,kv", [
    (None, 16, 16, 4, 2), (None, 8, 32, 4, 4), (16, 16, 16, 4, 1),
    (None, 64, 64, 6, 3), (8, 4, 8, 2, 2),
])
def test_flash_vs_quadratic(rng, window, qb, kb, heads, kv):
    cfg = _cfg(n_heads=heads, n_kv_heads=kv, head_dim=16,
               d_model=heads * 16, sliding_window=window)
    p = modules.attention_init(jax.random.PRNGKey(0), cfg)
    x = jnp.asarray(rng.normal(size=(2, 64, cfg.d_model)), jnp.float32)
    pos = jnp.arange(64)[None]
    out, _ = modules.attention_apply(p, x, cfg, positions=pos,
                                     q_block=qb, k_block=kb)
    want = _ref_attn(p, x, cfg, pos)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


def test_decode_matches_prefill(rng):
    cfg = _cfg()
    p = modules.attention_init(jax.random.PRNGKey(1), cfg)
    s = 24
    x = jnp.asarray(rng.normal(size=(2, s, 64)), jnp.float32)
    pos = jnp.arange(s)[None]
    full, cache = modules.attention_apply(p, x, cfg, positions=pos,
                                          q_block=8, k_block=8)
    pad = lambda a: jnp.pad(a, ((0, 0), (0, 0), (0, 40 - s), (0, 0)))
    cache = {"k": pad(cache["k"]), "v": pad(cache["v"])}
    # per-row offsets: row 0 decodes at s, row 1 at s (vector cache_len path)
    xt = jnp.asarray(rng.normal(size=(2, 1, 64)), jnp.float32)
    out, _ = modules.attention_apply(
        p, xt, cfg, positions=jnp.full((2, 1), s),
        kv_cache=cache, cache_len=jnp.asarray([s, s]))
    xfull = jnp.concatenate([x, xt], 1)
    want, _ = modules.attention_apply(
        p, xfull, cfg, positions=jnp.arange(s + 1)[None], q_block=1, k_block=1)
    np.testing.assert_allclose(np.asarray(out[:, 0]), np.asarray(want[:, -1]),
                               rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# SSD
# ---------------------------------------------------------------------------

@settings(max_examples=15, deadline=None)
@given(st.integers(1, 3), st.sampled_from([8, 16, 32]), st.integers(1, 4),
       st.sampled_from([4, 8]), st.sampled_from([2, 4]), st.integers(0, 10**6))
def test_ssd_chunked_equals_sequential(b, s, h, p, n, seed):
    key = jax.random.PRNGKey(seed)
    ks = jax.random.split(key, 5)
    x = jax.random.normal(ks[0], (b, s, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
    a = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.3)
    bb = jax.random.normal(ks[3], (b, s, n))
    cc = jax.random.normal(ks[4], (b, s, n))
    for chunk in (4, 8, s):
        if s % chunk:
            continue
        y1, s1 = ssd.ssd_chunked(x, dt, a, bb, cc, chunk)
        y2, s2 = ssd.ssd_sequential(x, dt, a, bb, cc)
        np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                                   rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(np.asarray(s1), np.asarray(s2),
                                   rtol=1e-4, atol=1e-4)


def test_mamba_block_decode_matches_full(rng):
    cfg = _cfg(family="ssm", n_heads=0, n_kv_heads=0, d_ff=0,
               ssm_state=16, ssm_head_dim=32, ssm_chunk=8)
    p = ssd.mamba_init(jax.random.PRNGKey(0), cfg)
    s = 16
    x = jnp.asarray(rng.normal(size=(2, s, cfg.d_model)), jnp.float32)
    full, fstate, fconv = ssd.mamba_apply(p, x, cfg)
    st_, conv = ssd.init_mamba_state(cfg, 2)
    outs = []
    for i in range(s):
        y, st_, conv = ssd.mamba_apply(p, x[:, i:i+1], cfg,
                                       ssm_state=st_, conv_state=conv)
        outs.append(y)
    dec = jnp.concatenate(outs, 1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full),
                               rtol=1e-3, atol=1e-3)
    np.testing.assert_allclose(np.asarray(st_), np.asarray(fstate),
                               rtol=1e-3, atol=1e-3)


def test_mamba_pad_mask_state_exact(rng):
    """Bucketed prefill: right-pads must not perturb the carried state."""
    cfg = _cfg(family="ssm", n_heads=0, n_kv_heads=0, d_ff=0,
               ssm_state=8, ssm_head_dim=32, ssm_chunk=4)
    p = ssd.mamba_init(jax.random.PRNGKey(0), cfg)
    x = jnp.asarray(rng.normal(size=(1, 12, cfg.d_model)), jnp.float32)
    _, state_exact, conv_exact = ssd.mamba_apply(p, x, cfg)
    xpad = jnp.pad(x, ((0, 0), (0, 4), (0, 0)))
    mask = (jnp.arange(16) < 12).astype(jnp.float32)[None]
    _, state_pad, conv_pad = ssd.mamba_apply(
        p, xpad, cfg, pad_mask=mask, last_valid=jnp.asarray([12]))
    np.testing.assert_allclose(np.asarray(state_pad), np.asarray(state_exact),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(conv_pad), np.asarray(conv_exact),
                               rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# MoE dispatch
# ---------------------------------------------------------------------------

@settings(max_examples=30, deadline=None)
@given(st.integers(2, 8), st.integers(1, 3), st.integers(4, 64),
       st.sampled_from([4, 8, 16]), st.integers(0, 10**6))
def test_dispatch_capacity_invariants(e, k, t, cap, seed):
    key = jax.random.PRNGKey(seed)
    k = min(k, e)
    ids = jax.random.randint(key, (t, k), 0, e)
    w = jax.nn.softmax(jax.random.normal(key, (t, k)), -1)
    d = moe_lib.dispatch_by_expert(ids, w, e, cap)
    dest = np.asarray(d.dest)
    kept = dest < e * cap
    # each expert receives at most `cap` rows
    counts = np.bincount(dest[kept] // cap, minlength=e)
    assert (counts <= cap).all()
    # kept rows keep their gate weight; dropped rows zero
    assert (np.asarray(d.weight)[~kept] == 0).all()
    # no two kept assignments share a destination
    assert len(np.unique(dest[kept])) == kept.sum()


def test_moe_pad_tokens_never_consume_capacity(rng):
    cfg = _cfg(family="moe", n_experts=4, experts_per_token=2)
    p = moe_lib.moe_init(jax.random.PRNGKey(0), cfg)
    x = jnp.asarray(rng.normal(size=(1, 8, cfg.d_model)), jnp.float32)
    mask = (jnp.arange(8) < 5).astype(jnp.float32)[None]
    y_mask, _ = moe_lib.moe_apply(p, x, cfg, capacity=8, token_mask=mask)
    y_exact, _ = moe_lib.moe_apply(p, x[:, :5], cfg, capacity=8)
    np.testing.assert_allclose(np.asarray(y_mask[:, :5]), np.asarray(y_exact),
                               rtol=1e-4, atol=1e-4)
    assert np.abs(np.asarray(y_mask[:, 5:])).max() == 0.0
