"""Core paper mechanism: packet format, bank residency, pipeline, sigma/Pi."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st

from repro.core import bank as bank_lib
from repro.core import executor, packet as pkt, pipeline


@pytest.fixture(scope="module")
def bank2():
    return executor.init_bank(jax.random.PRNGKey(0), 2)


@pytest.fixture(scope="module")
def payload(rng16=None):
    rng = np.random.default_rng(0)
    return rng.integers(0, 2**32, (64, pkt.PAYLOAD_WORDS), dtype=np.uint32)


def test_packet_layout(payload):
    slots = np.arange(64) % 2
    p = pkt.make_packets(slots, payload, control=int(pkt.CTRL_MONITOR_ONLY))
    assert p.shape == (64, pkt.PACKET_WORDS)
    assert p.dtype == np.uint32
    np.testing.assert_array_equal(p[:, pkt.SLOT_WORD], slots)
    assert (p[:, pkt.VERSION_WORD] == pkt.FORMAT_VERSION).all()
    np.testing.assert_array_equal(p[:, pkt.META_WORDS:], payload)
    # 1088 bytes total, 1024 payload, 64 metadata
    assert pkt.PACKET_BYTES == 1088 and pkt.PAYLOAD_BYTES == 1024


def test_sigma_clamps_out_of_range(payload):
    p = pkt.make_packets(np.asarray([0, 1, 7, 2**31 - 1] * 16), payload)
    slots = pkt.slot_of(jnp.asarray(p), num_slots=2)
    assert int(slots.max()) <= 1


def test_action_pi(bank2, payload):
    p = pkt.make_packets(np.zeros(64), payload)
    scores = jnp.asarray(np.linspace(-1, 1, 64), jnp.float32)
    acts = pkt.decide_action(jnp.asarray(p), scores)
    mal = np.asarray(scores) > 0
    assert (np.asarray(acts)[mal] == pkt.ACTION_DROP).all()
    assert (np.asarray(acts)[~mal] == pkt.ACTION_FORWARD).all()
    # monitor-only control bit: malicious -> FLAG instead of DROP
    p2 = pkt.make_packets(np.zeros(64), payload, control=int(pkt.CTRL_MONITOR_ONLY))
    acts2 = pkt.decide_action(jnp.asarray(p2), scores)
    assert (np.asarray(acts2)[mal] == pkt.ACTION_FLAG).all()


def test_bank_residency_and_update(bank2):
    assert bank_lib.bank_size(bank2) == 2
    f0 = bank_lib.select_slot(bank2, 0)
    f1 = bank_lib.select_slot(bank2, 1)
    assert not np.array_equal(np.asarray(f0["w1p"]), np.asarray(f1["w1p"]))
    # control-plane style replacement hits only the targeted slot
    newbank = bank_lib.update_slot(bank2, 0, f1)
    np.testing.assert_array_equal(
        np.asarray(newbank["w1p"][0]), np.asarray(f1["w1p"]))
    np.testing.assert_array_equal(
        np.asarray(newbank["w1p"][1]), np.asarray(bank2["w1p"][1]))


def test_footprint_matches_paper_scale():
    """Paper Table II: one h32 slot ~32.9 KB; 2 slots ~64.3 KB; 16 ~514.6 KB."""
    per = executor.H32.param_bytes()
    assert abs(per - 32932) < 512          # within a file-header of the paper
    assert abs(2 * per / 1024 - 64.3) < 1.0
    assert abs(16 * per / 1024 - 514.6) < 8.0


@pytest.mark.parametrize("strategy", ["take", "onehot", "grouped"])
def test_pipeline_strategies_agree(bank2, payload, strategy):
    slots = np.random.default_rng(1).integers(0, 2, 64)
    p = jnp.asarray(pkt.make_packets(slots, payload))
    base = pipeline.packet_step(bank2, p, num_slots=2, strategy="take")
    res = pipeline.packet_step(bank2, p, num_slots=2, strategy=strategy)
    np.testing.assert_array_equal(np.asarray(res.slots), slots)
    np.testing.assert_allclose(
        np.asarray(res.scores), np.asarray(base.scores), atol=1e-3)
    np.testing.assert_array_equal(
        np.asarray(res.verdicts), np.asarray(base.verdicts))


def test_fixed_slot_baseline(bank2, payload):
    """The paper's baseline operating mode: sigma replaced by a constant."""
    slots = np.random.default_rng(2).integers(0, 2, 64)
    p = jnp.asarray(pkt.make_packets(slots, payload))
    res = pipeline.packet_step(bank2, p, num_slots=2, fixed_slot=1)
    assert (np.asarray(res.slots) == 1).all()


def test_single_sample_slot_flip(bank2, payload):
    """Paper §III-C: changing ONLY reg0 changes the verdict score."""
    p0 = pkt.make_packets(np.zeros(1), payload[:1])
    p1 = pkt.make_packets(np.ones(1), payload[:1])
    s0 = float(pipeline.packet_step(bank2, jnp.asarray(p0), num_slots=2).scores[0])
    s1 = float(pipeline.packet_step(bank2, jnp.asarray(p1), num_slots=2).scores[0])
    assert s0 != s1  # payload identical; only the slot field differs


# ---------------------------------------------------------------------------
# grouping properties
# ---------------------------------------------------------------------------

@settings(max_examples=30, deadline=None)
@given(
    st.integers(2, 8),                       # num_slots
    st.integers(1, 4).map(lambda x: 8 * x),  # batch
    st.sampled_from([4, 8]),                 # block
    st.randoms(),
)
def test_padded_grouping_exact(num_slots, batch, block, pyrng):
    slots = jnp.asarray(
        [pyrng.randrange(num_slots) for _ in range(batch)], jnp.int32)
    g = bank_lib.group_by_slot_padded(slots, num_slots, block)
    x = jnp.arange(batch, dtype=jnp.float32)[:, None] + 1.0
    x_pad = bank_lib.scatter_padded(x, g)
    # every block single-slot
    blocks = np.asarray(g.block_slots)
    assert x_pad.shape[0] == g.b_pad and g.b_pad % block == 0
    # roundtrip: gather recovers the original rows exactly
    back = bank_lib.gather_padded(x_pad, g)
    np.testing.assert_array_equal(np.asarray(back), np.asarray(x))
    # rows landed in a block whose slot matches theirs
    dest_block = np.asarray(g.dest) // block
    np.testing.assert_array_equal(
        blocks[dest_block], np.asarray(slots)[np.asarray(g.order)])
