"""End-to-end behaviour tests for the paper's system (BoundSwitch-JAX).

The full packet path: train both resident slots -> preload bank -> replay a
boundary stream -> assert the paper's three headline properties:
  1. inline BNN execution is lightweight (selection << inference),
  2. metadata-driven selection induces distinct behaviors on one path,
  3. online switching has zero wrong-verdict packets at the boundary.
"""

import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import bank as bank_lib
from repro.core import executor, packet as pkt, pipeline, switching
from repro.data import packets as pk
from repro.train import bnn


@pytest.fixture(scope="module")
def system():
    slot0, slot1 = bnn.train_slot_pair(seed=0, epochs=2, samples_per_group=384)
    bank = bank_lib.stack_bank([slot0, slot1])
    xb, yb = pk.load_split("val", 256, 0)
    payload = pk.to_payload_words(xb)
    return bank, payload, yb


def test_end_to_end_boundary_run(system):
    bank, payload, _ = system
    n = 256
    trace = switching.boundary_trace(n, payload[:n])
    res = switching.replay_trace(bank, trace, num_slots=2, batch=1)
    assert res.wrong_slot == 0
    assert res.wrong_verdict == 0
    # continuity: boundary gap comparable to median (paper: 95.6 vs 93.0 us)
    g = res.gap_stats_us()
    assert g["boundary_gap_us"] < 5 * g["median_gap_us"] + 50


def test_selection_much_cheaper_than_inference(system):
    bank, payload, _ = system
    p = jnp.asarray(pkt.make_packets(np.zeros(256), payload[:256]))
    sel = lambda: pipeline.slot_select_only(p, 2).block_until_ready()
    inf = lambda: pipeline.inference_only(
        bank_lib.select_slot(bank, 0), pkt.payload_of(p)).block_until_ready()
    sel(); inf()
    t0 = time.perf_counter()
    for _ in range(30):
        sel()
    t_sel = time.perf_counter() - t0
    t0 = time.perf_counter()
    for _ in range(30):
        inf()
    t_inf = time.perf_counter() - t0
    assert t_sel < t_inf, (t_sel, t_inf)


def test_distinct_behaviors_same_path(system):
    bank, payload, labels = system
    n = min(256, payload.shape[0])
    p0 = jnp.asarray(pkt.make_packets(np.zeros(n), payload[:n]))
    p1 = jnp.asarray(pkt.make_packets(np.ones(n), payload[:n]))
    v0 = np.asarray(pipeline.packet_step(bank, p0, num_slots=2).verdicts)
    v1 = np.asarray(pipeline.packet_step(bank, p1, num_slots=2).verdicts)
    y = labels[:n].astype(bool)
    # slot0 recall-oriented: catches at least as many positives
    assert (v0 & y).sum() >= (v1 & y).sum()
    # behaviors genuinely differ
    assert (v0 != v1).any()


def test_scaling_to_16_slots_correct_selection(system):
    """Paper §III-B: the same two weight sets alternated across 16 resident
    slots; correct slot selection preserved for all 16 ids."""
    bank2, payload, _ = system
    f0 = bank_lib.select_slot(bank2, 0)
    f1 = bank_lib.select_slot(bank2, 1)
    bank16 = bank_lib.stack_bank([f0 if i % 2 == 0 else f1 for i in range(16)])
    assert bank_lib.bank_size(bank16) == 16
    n = 128
    slots = np.arange(n) % 16
    p = jnp.asarray(pkt.make_packets(slots, payload[:n]))
    res = pipeline.packet_step(bank16, p, num_slots=16)
    np.testing.assert_array_equal(np.asarray(res.slots), slots)
    # slot i behaves exactly like its source weight set
    base0 = pipeline.packet_step(bank2, jnp.asarray(
        pkt.make_packets(np.zeros(n), payload[:n])), num_slots=2)
    even = slots % 2 == 0
    np.testing.assert_allclose(np.asarray(res.scores)[even],
                               np.asarray(base0.scores)[even], atol=1e-4)
