"""Checkpoint store: roundtrip, atomic commit, GC, elastic restore across
device counts (subprocess)."""

import os
import subprocess
import sys
import tempfile
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st

from repro.checkpoint import store


def _tree(rng):
    return {
        "a": {"w": jnp.asarray(rng.normal(size=(4, 8)), jnp.float32)},
        "b": jnp.asarray(rng.normal(size=(3,)), jnp.bfloat16),
        "c": jnp.asarray(rng.integers(0, 10, (2, 2)), jnp.int32),
        "step": jnp.asarray(7, jnp.int32),
    }


def test_roundtrip_exact(rng):
    t = _tree(rng)
    with tempfile.TemporaryDirectory() as d:
        store.save(d, 3, t, extra={"data_cursor": 11})
        like = jax.tree_util.tree_map(jnp.zeros_like, t)
        back, extra = store.restore(d, None, like)
        assert extra["data_cursor"] == 11
        for a, b in zip(jax.tree_util.tree_leaves(t),
                        jax.tree_util.tree_leaves(back)):
            assert a.dtype == b.dtype
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_keep_last_gc(rng):
    t = _tree(rng)
    with tempfile.TemporaryDirectory() as d:
        for s in range(5):
            store.save(d, s, t, keep_last=2)
        assert store.list_steps(d) == [3, 4]
        assert store.latest_step(d) == 4


def test_no_tmp_left_behind(rng):
    t = _tree(rng)
    with tempfile.TemporaryDirectory() as d:
        store.save(d, 1, t)
        assert not any(n.endswith(".tmp") for n in os.listdir(d))


def _manifest(ckpt_dir, step):
    import msgpack
    path = os.path.join(ckpt_dir, f"step_{step:08d}", "MANIFEST.msgpack")
    with open(path, "rb") as f:
        return msgpack.unpackb(f.read())


def test_leaf_extension_matches_recorded_codec(rng):
    t = _tree(rng)
    with tempfile.TemporaryDirectory() as d:
        store.save(d, 1, t)
        m = _manifest(d, 1)
        assert m["codec"] in ("zstd", "zlib")
        ext = ".bin." + {"zstd": "zst", "zlib": "zlib"}[m["codec"]]
        ckpt = os.path.join(d, "step_00000001")
        for e in m["leaves"]:
            assert e["file"].endswith(ext)
            assert os.path.exists(os.path.join(ckpt, e["file"]))


def test_zlib_fallback_writes_zlib_extension_and_roundtrips(rng, monkeypatch):
    t = _tree(rng)
    with tempfile.TemporaryDirectory() as d:
        monkeypatch.setattr(store, "zstd", None)  # container without zstandard
        store.save(d, 2, t)
        m = _manifest(d, 2)
        assert m["codec"] == "zlib"
        assert all(e["file"].endswith(".bin.zlib") for e in m["leaves"])
        like = jax.tree_util.tree_map(jnp.zeros_like, t)
        back, _ = store.restore(d, 2, like)
        for a, b in zip(jax.tree_util.tree_leaves(t),
                        jax.tree_util.tree_leaves(back)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_legacy_zlib_leaves_under_zst_suffix_still_restore(rng, monkeypatch):
    """Pre-fix fallback checkpoints wrote zlib bytes into ``.bin.zst``
    files; the manifest codec (not the suffix) drives restore."""
    t = _tree(rng)
    with tempfile.TemporaryDirectory() as d:
        monkeypatch.setattr(store, "zstd", None)
        monkeypatch.setattr(
            store, "_leaf_file",
            lambda ps, codec: store.hashlib.sha1(
                ps.encode()).hexdigest()[:16] + ".bin.zst")
        store.save(d, 3, t)
        m = _manifest(d, 3)
        assert m["codec"] == "zlib"
        assert all(e["file"].endswith(".bin.zst") for e in m["leaves"])
        monkeypatch.undo()  # restore with real module state (zstd or not)
        like = jax.tree_util.tree_map(jnp.zeros_like, t)
        back, _ = store.restore(d, 3, like)
        for a, b in zip(jax.tree_util.tree_leaves(t),
                        jax.tree_util.tree_leaves(back)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_shape_mismatch_raises(rng):
    t = _tree(rng)
    with tempfile.TemporaryDirectory() as d:
        store.save(d, 1, t)
        bad = dict(t, b=jnp.zeros((5,), jnp.bfloat16))
        with pytest.raises(ValueError, match="shape mismatch"):
            store.restore(d, 1, bad)


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 10**6))
def test_roundtrip_property(seed):
    rng = np.random.default_rng(seed)
    t = {"x": jnp.asarray(rng.normal(size=(rng.integers(1, 5),)), jnp.float32)}
    with tempfile.TemporaryDirectory() as d:
        store.save(d, 0, t)
        back, _ = store.restore(d, 0, t)
        np.testing.assert_array_equal(np.asarray(t["x"]), np.asarray(back["x"]))


@pytest.mark.slow
def test_elastic_restore_across_device_counts(rng):
    """Save on 1 device; restore + reshard on 8 fake devices in a subprocess
    (the elastic-scaling path)."""
    t = {"w": jnp.asarray(rng.normal(size=(16, 8)), jnp.float32)}
    with tempfile.TemporaryDirectory() as d:
        store.save(d, 5, t, extra={"mesh": "1dev"})
        script = textwrap.dedent(f"""
            import os
            os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
            import sys; sys.path.insert(0, {repr(os.path.join(os.path.dirname(__file__), '..', 'src'))})
            import jax, jax.numpy as jnp, numpy as np
            from jax.sharding import NamedSharding, PartitionSpec as P
            from repro.checkpoint import store
            mesh = jax.make_mesh((4, 2), ("data", "model"))
            like = {{"w": jnp.zeros((16, 8), jnp.float32)}}
            sh = {{"w": NamedSharding(mesh, P("data", "model"))}}
            tree, extra = store.restore({repr(d)}, 5, like, shardings=sh)
            assert extra["mesh"] == "1dev"
            assert len(tree["w"].sharding.device_set) == 8
            print("ELASTIC_OK", float(tree["w"].sum()))
        """)
        out = subprocess.run([sys.executable, "-c", script],
                             capture_output=True, text=True, timeout=300)
        assert "ELASTIC_OK" in out.stdout, out.stderr[-2000:]
        want = float(jnp.sum(t["w"]))
        got = float(out.stdout.split("ELASTIC_OK")[1].strip())
        assert abs(got - want) < 1e-4
