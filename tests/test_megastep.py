"""Device-resident megastep (DESIGN.md §13): the deferred N-tick scan
window must be observationally identical to N sequential ``tick()``
calls — verdicts, slots, actions, telemetry count totals, epoch apply
ticks — including mid-window SwapSlot / ProgramReta epochs, and the
engine must fall back to the sequential loop whenever the configuration
needs per-tick host control (fault injection, non-fused strategies)."""

import jax
import numpy as np
import pytest

from _hyp import given, settings, st
from repro import deploy
from repro.control import ProgramReta, SwapSlot
from repro.core import executor, packet as pkt
from repro.dataplane import DataplaneRuntime, faults
from repro.dataplane.workloads.phases import SEQ_WORD
from repro.obs import TelemetryStream, attach

NUM_QUEUES = 2
NUM_SLOTS = 2
BATCH = 8


@pytest.fixture(scope="module")
def bank2():
    return executor.init_bank(jax.random.PRNGKey(0), NUM_SLOTS)


def _make_bursts(seed: int, sizes: list[int]) -> list[np.ndarray]:
    """Per-tick bursts over a tiny payload pool: repeated suffixes (the
    megastep's dedup fast path) mixed with per-packet word-0 twists and
    a few fully unique payloads (the no-sharing path)."""
    rng = np.random.default_rng(seed)
    pool = rng.integers(0, 2**32, (3, pkt.PAYLOAD_WORDS), dtype=np.uint32)
    seq = 0
    bursts = []
    for n in sizes:
        if n == 0:
            bursts.append(np.zeros((0, pkt.PACKET_WORDS), np.uint32))
            continue
        payload = pool[rng.integers(0, pool.shape[0], n)].copy()
        payload[:, 0] ^= rng.integers(0, 2**32, n, dtype=np.uint32)
        unique = rng.random(n) < 0.2  # some rows share no suffix at all
        payload[unique] = rng.integers(
            0, 2**32, (int(unique.sum()), pkt.PAYLOAD_WORDS), dtype=np.uint32)
        rows = pkt.make_packets(
            rng.integers(0, NUM_SLOTS, n).astype(np.int32), payload)
        rows[:, pkt.CONTROL_WORD_LO] = rng.integers(0, 2, n).astype(np.uint32)
        rows[:, SEQ_WORD] = np.arange(seq, seq + n, dtype=np.uint32)
        seq += n
        bursts.append(rows)
    return bursts


def _drive(bank, bursts, epochs, megastep_ticks, *, audit=True,
           fault_injector=None, strategy="fused"):
    rt = DataplaneRuntime(
        bank, num_queues=NUM_QUEUES, strategy=strategy, batch=BATCH,
        ring_capacity=256, audit=audit, record=True,
        megastep_ticks=megastep_ticks, fault_injector=fault_injector)
    for t, burst in enumerate(bursts):
        for cmd in epochs.get(t, ()):
            rt.control.submit(cmd)
        rt.dispatch(burst)
        rt.tick()
    rt.drain()
    return rt


def _observed(rt) -> tuple:
    """Everything the bit-exactness contract covers, as one comparable
    value: per-queue completion streams, counting telemetry, epoch apply
    ticks.  (Wall-clock fields — busy_s, latency — are excluded.)"""
    queues = []
    for q, qs in enumerate(rt.snapshot()["queues"]):
        queues.append((
            tuple(rt.completed_seq[q]),
            tuple(rt.completed_verdicts[q]),
            tuple(rt.completed_slots[q]),
            qs["completed"], qs["dropped"],
            tuple(qs["per_slot_total"]), tuple(qs["per_slot_malicious"]),
            tuple(sorted(qs["actions"].items())),
        ))
    epochs = tuple((r.applied_tick, type(r.commands[0]).__name__)
                   for r in rt.control.log if r.applied)
    return (tuple(queues), epochs, rt.telemetry.slot_swaps,
            rt.telemetry.reta_updates)


@settings(max_examples=20, deadline=None)
@given(
    seed=st.integers(0, 2**16),
    sizes=st.lists(st.integers(0, 24), min_size=3, max_size=10),
    window=st.sampled_from([2, 3, 8]),
    swap_at=st.integers(0, 9),
    reta_at=st.integers(0, 9),
)
def test_megastep_equals_sequential(bank2, seed, sizes, window, swap_at,
                                    reta_at):
    """megastep(n) == n sequential ticks, bit for bit, with SwapSlot and
    ProgramReta epochs landing mid-window (both runs audited)."""
    bursts = _make_bursts(seed, sizes)
    epochs = {
        swap_at: [SwapSlot(swap_at % NUM_SLOTS,
                           executor.init_params(jax.random.PRNGKey(seed)))],
    }
    epochs.setdefault(reta_at, []).append(
        ProgramReta(tuple(int(x) for x in (np.arange(16) + reta_at)
                          % NUM_QUEUES)))
    rt_seq = _drive(bank2, bursts, epochs, 1)
    rt_meg = _drive(bank2, bursts, epochs, window)
    assert rt_meg._mega is not None  # the deferred engine actually ran
    assert _observed(rt_seq) == _observed(rt_meg)
    assert rt_seq.telemetry.wrong_verdict == 0
    assert rt_meg.telemetry.wrong_verdict == 0
    assert rt_seq.audit_conservation()["ok"]
    assert rt_meg.audit_conservation()["ok"]


def test_fault_injection_falls_back_to_sequential(bank2):
    """An armed injector needs per-tick host control: the runtime must
    run the sequential loop (no megastep engine) and still pass the
    audits through an injected stall."""
    plan = faults.FaultPlan(faults=(faults.StallHost(0, 2, 2),))
    bursts = _make_bursts(7, [16] * 8)
    rt = _drive(bank2, bursts, {}, 8,
                fault_injector=faults.FaultInjector(plan))
    assert rt._mega is None
    assert rt.telemetry.wrong_verdict == 0
    assert rt.audit_conservation()["ok"]
    # same traffic without the stall, deferred: identical completions
    # once both runs drain (the stall only delays, never drops)
    rt_meg = _drive(bank2, bursts, {}, 8)
    for q in range(NUM_QUEUES):
        assert sorted(rt.completed_seq[q]) == sorted(rt_meg.completed_seq[q])


def test_non_fused_strategies_fall_back_to_sequential(bank2):
    """The megastep's batched forward replicates the fused/ref path only;
    other strategies keep the per-tick loop (contract trivially holds)."""
    bursts = _make_bursts(11, [12] * 4)
    rt = _drive(bank2, bursts, {}, 8, strategy="take")
    assert rt._mega is None
    assert rt.audit_conservation()["ok"]


def test_megastep_batched_retires_respect_sampler_and_stream_bounds(bank2):
    """Whole-megastep drains hand the deploy/obs taps a window's worth of
    retires back to back: ``PacketSampler.max_pending`` must still bound
    the labeling backlog, and ``TelemetryStream`` overflow accounting
    must stay conserved (``next_sid == buffered + dropped_events``)."""
    pool, labels = deploy.labeled_pool(samples_per_group=64, seed=0)
    oracle = deploy.LabelOracle(pool, labels)
    rt = DataplaneRuntime(bank2, num_queues=NUM_QUEUES, strategy="fused",
                          batch=16, ring_capacity=1024, megastep_ticks=8)
    max_pending = 3
    sampler = deploy.PacketSampler(oracle, num_slots=NUM_SLOTS, per_tick=8,
                                   max_pending=max_pending).attach(rt)
    stream = TelemetryStream(capacity=4)  # tiny: force real overflow
    attach(rt, stream)
    flush_sizes = []
    orig_flush = sampler.flush
    sampler.flush = lambda: (flush_sizes.append(len(sampler._pending)),
                             orig_flush())[-1]
    rng = np.random.default_rng(0)
    peak = 0
    for _ in range(40):
        idx = rng.integers(0, pool.shape[0], 48)
        rt.dispatch(pkt.make_packets(
            rng.integers(0, NUM_SLOTS, 48).astype(np.int32), pool[idx]))
        rt.tick()
        peak = max(peak, len(sampler._pending))
    rt.drain()
    peak = max(peak, len(sampler._pending))
    sampler.detach()  # final flush
    completed = rt.snapshot()["completed_total"]
    assert completed > 0
    assert sampler.seen == completed
    # the backlog bound held across every batched retire burst
    assert peak <= max_pending
    assert max(flush_sizes, default=0) <= max_pending
    assert sampler.labeled + sampler.unknown == sampler.sampled
    # stream conservation: every event is either retained or counted out
    s = stream.snapshot_stats()
    assert s["next_sid"] == s["buffered"] + s["dropped_events"]
    assert s["dropped_events"] > 0  # the tiny ring really overflowed
