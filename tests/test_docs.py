"""The documentation layer stays wired to the code: CLI reference /
parser flag parity, the doc-lint checks themselves, and the presence of
the README + DESIGN.md §14 the docs CI step gates on."""

import os
import re

from repro.launch import doclint
from repro.launch.dataplane import build_parser

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _live_flags() -> set:
    flags = {opt for a in build_parser()._actions
             for opt in a.option_strings if opt.startswith("--")}
    flags.discard("--help")
    return flags


def test_cli_reference_matches_live_parser():
    """Every parser flag is documented and every documented flag exists
    — docs/cli.md cannot rot in either direction."""
    text = open(os.path.join(ROOT, "docs", "cli.md")).read()
    documented = set(re.findall(r"`(--[\w-]+)[^`]*`", text))
    live = _live_flags()
    assert live - documented == set(), f"undocumented: {live - documented}"
    assert documented - live == set(), f"rotted: {documented - live}"


def test_new_slot_cache_flags_exist():
    assert {"--slot-cache", "--prefetch"} <= _live_flags()


def test_doclint_clean():
    """The full docs lint (dead paths, dead module refs, broken links
    and anchors, §N references, flag parity, API docstrings) passes on
    the committed tree — the same check CI runs."""
    assert doclint.run(ROOT) == []


def test_readme_covers_required_sections():
    text = open(os.path.join(ROOT, "README.md")).read()
    assert "## Quickstart" in text
    assert "## Architecture" in text
    assert "## Benchmarks" in text
    assert "examples/quickstart.py" in text
    assert "python -m pytest -x -q" in text         # tier-1 verify command
    for n in range(1, 11):
        assert f"BENCH_{n}.json" in text            # figure <-> baseline map


def test_design_has_section_14():
    text = open(os.path.join(ROOT, "DESIGN.md")).read()
    assert re.search(r"^## §14 ", text, re.M)
    for phrase in ("pointer flip", "shadow", "LRU", "prefetch"):
        assert phrase in text
