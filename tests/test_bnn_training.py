"""BNN training (Fig. 6 semantics): pos_weight-conditioned slots expose
recall- vs precision-oriented behavior; packed executor == latent forward."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import executor
from repro.data import packets as pk
from repro.train import bnn


@pytest.fixture(scope="module")
def slots():
    return bnn.train_slot_pair(seed=0, epochs=2, samples_per_group=384)


@pytest.fixture(scope="module")
def val():
    xb, yb = pk.load_split("val", 384, 0)
    return pk.to_payload_words(xb), yb


def test_dataset_is_learnable_and_balanced():
    xb, yb = pk.load_split("train", 256, 0)
    assert xb.shape == (6 * 256, 1024)
    frac = yb.mean()
    assert 0.15 < frac < 0.45


def test_slot_conditioned_behavior(slots, val):
    """Paper Fig. 6: slot 0 (pos_weight 4.0) recall-oriented, slot 1
    (pos_weight 0.5) precision-oriented."""
    s0, s1 = slots
    w, y = val
    m0 = bnn.evaluate(s0, w, y)
    m1 = bnn.evaluate(s1, w, y)
    assert m0["recall"] > m1["recall"], (m0, m1)
    assert m1["precision"] >= m0["precision"], (m0, m1)
    assert m0["f1"] > 0.5  # actually learned the task


def test_single_sample_score_flip(slots, val):
    """Paper §III-C: holding the payload fixed, changing only the slot field
    changes the output score."""
    s0, s1 = slots
    w, y = val
    from repro.core import bank as bank_lib, packet as pkt, pipeline
    bank = bank_lib.stack_bank([s0, s1])
    # find a malicious sample where the slots disagree (the paper's example)
    sc0 = np.asarray(executor.forward(s0, jnp.asarray(w))[:, 0])
    sc1 = np.asarray(executor.forward(s1, jnp.asarray(w))[:, 0])
    idx = int(np.argmax(np.abs(sc0 - sc1)))
    p0 = pkt.make_packets(np.zeros(1), w[idx:idx + 1])
    p1 = pkt.make_packets(np.ones(1), w[idx:idx + 1])
    r0 = pipeline.packet_step(bank, jnp.asarray(p0), num_slots=2)
    r1 = pipeline.packet_step(bank, jnp.asarray(p1), num_slots=2)
    assert float(r0.scores[0]) == pytest.approx(sc0[idx], abs=1e-4)
    assert float(r1.scores[0]) == pytest.approx(sc1[idx], abs=1e-4)
    assert float(r0.scores[0]) != float(r1.scores[0])


def test_packed_matches_latent(slots):
    """pack_trained preserves the decision function of the STE latent."""
    key = jax.random.PRNGKey(3)
    latent = bnn.init_latent(key)
    x = np.sign(np.random.default_rng(0).normal(size=(32, 8192))).astype(np.float32)
    x[x == 0] = 1.0
    latent_scores = np.asarray(bnn.latent_forward(latent, jnp.asarray(x))[:, 0])
    packed = bnn.pack_trained(latent)
    xp = np.packbits((x < 0).astype(np.uint8), axis=-1,
                     bitorder="little").view("<u4")
    packed_scores = np.asarray(
        executor.forward(packed, jnp.asarray(xp))[:, 0])
    # identical hidden signs => identical scores up to the sqrt(d) rescale
    h_lat = np.sign(x @ np.sign(np.asarray(latent["w1"])).T
                    + np.asarray(latent["b1"]) * np.sqrt(8192))
    np.testing.assert_allclose(packed_scores, latent_scores, rtol=1e-3,
                               atol=1e-3)
